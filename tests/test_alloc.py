"""Allocator v2 (PR 7): size classes, slabs, arenas, compaction, rollback.

Unit coverage for :mod:`repro.core.alloc` plus the pool-level contracts the
allocator underwrites: atomic alloc rollback (satellite 1), orphan audits
after drains/recovery, effective-capacity sizing, and property tests over
random alloc/write/free/compact/resize interleavings — reads stay
bit-identical, allocator bookkeeping stays consistent with the directory,
and fragmentation never increases across a compaction pass.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.alloc import (
    DEFAULT_ARENA,
    MIN_CLASS_BYTES,
    SlabAllocator,
    object_footprint_bytes,
    size_class_bytes,
)
from repro.core.pool import MemoryPool, OrphanExtentError
from repro.core.sizing import effective_node_capacity, pool_nodes_needed

from tests._hypothesis_compat import given, settings, st

KIB = 1 << 10
STRIPE = 32 * KIB


def _blob(nbytes: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.integers(0, 256, size=nbytes, dtype=np.uint8)


def _assert_all_readable(pool: MemoryPool, expected: dict) -> None:
    for name, blob in expected.items():
        got, _ = pool.read_object(name)
        assert np.array_equal(got, blob), f"{name} diverged"


class TestSizeClasses:
    def test_power_of_two_rounding(self):
        assert size_class_bytes(1, stripe_bytes=STRIPE) == MIN_CLASS_BYTES
        assert size_class_bytes(4096, stripe_bytes=STRIPE) == 4096
        assert size_class_bytes(4097, stripe_bytes=STRIPE) == 8192
        assert size_class_bytes(STRIPE, stripe_bytes=STRIPE) == STRIPE

    def test_top_class_is_exactly_the_stripe(self):
        # even for a non-power-of-two stripe: full stripes pay no internal
        # fragmentation (a 24K extent in a 24K slot, not a 32K one)
        odd = 24 * KIB
        assert size_class_bytes(odd, stripe_bytes=odd) == odd
        assert size_class_bytes(20 * KIB, stripe_bytes=odd) == odd

    def test_oversized_rejected(self):
        with pytest.raises(ValueError):
            size_class_bytes(STRIPE + 1, stripe_bytes=STRIPE)

    def test_footprint(self):
        # tail-only object: rounds to its class
        assert object_footprint_bytes(5 * KIB, stripe_bytes=STRIPE) == 8 * KIB
        # exact stripes: no rounding at all
        assert object_footprint_bytes(2 * STRIPE, stripe_bytes=STRIPE) \
            == 2 * STRIPE
        # stripes + tail: full stripes plus the class-rounded tail
        assert object_footprint_bytes(STRIPE + 1, stripe_bytes=STRIPE) \
            == STRIPE + MIN_CLASS_BYTES
        # empty object still occupies one minimum-class slot
        assert object_footprint_bytes(0, stripe_bytes=STRIPE) \
            == MIN_CLASS_BYTES

    @settings(max_examples=50, deadline=None)
    @given(st.integers(1, 3 * STRIPE))
    def test_footprint_bounds(self, nbytes):
        fp = object_footprint_bytes(nbytes, stripe_bytes=STRIPE)
        assert fp >= nbytes
        # waste is the tail's class slack: zero for exact stripes, under
        # one min-class slot for tiny tails, < tail itself otherwise
        # (the class is the smallest power of two >= the tail)
        waste = fp - nbytes
        tail = nbytes % STRIPE
        if tail == 0:
            assert waste == 0
        else:
            assert waste < max(MIN_CLASS_BYTES, tail)
        assert waste < STRIPE


class TestSlabAllocator:
    def test_place_release_roundtrip(self):
        a = SlabAllocator(stripe_bytes=STRIPE)
        a.place(0, "x#e0", 5 * KIB)
        assert a.has(0, "x#e0")
        assert a.nbytes_of(0, "x#e0") == 5 * KIB
        assert a.arena_of(0, "x#e0") == DEFAULT_ARENA
        s = a.stats()
        assert s["live_bytes"] == 5 * KIB
        assert s["held_bytes"] == STRIPE          # one carved slab
        assert s["internal_frag_bytes"] == 3 * KIB  # 5K in an 8K slot
        a.release(0, "x#e0")
        assert not a.has(0, "x#e0")
        assert a.stats()["held_bytes"] == 0        # emptied slab returned

    def test_duplicate_place_rejected_and_release_tolerant(self):
        a = SlabAllocator(stripe_bytes=STRIPE)
        a.place(0, "x#e0", KIB)
        with pytest.raises(ValueError):
            a.place(0, "x#e0", KIB)
        a.release(0, "never-placed")               # no-op, like the store
        a.release(3, "x#e0")                       # wrong node: no-op too
        assert a.has(0, "x#e0")

    def test_prefers_fullest_partial_slab(self):
        a = SlabAllocator(stripe_bytes=STRIPE)     # 8 slots per 4K slab
        for i in range(16):                        # two full slabs
            a.place(0, f"k{i}#e0", 4 * KIB)
        # empty slab 0 down to 1 slot, slab 1 down to 7
        for i in range(7):
            a.release(0, f"k{i}#e0")
        a.release(0, "k8#e0")
        slabs = {s.slab_id: s.used_slots for s in a.slabs_on(0)}
        fullest = max(slabs, key=lambda sid: slabs[sid])
        a.place(0, "new#e0", 4 * KIB)
        slabs2 = {s.slab_id: s.used_slots for s in a.slabs_on(0)}
        assert slabs2[fullest] == slabs[fullest] + 1

    def test_arenas_never_share_slabs(self):
        a = SlabAllocator(stripe_bytes=STRIPE)
        a.place(0, "h#e0", 4 * KIB, arena="hpc")
        a.place(0, "s#e0", 4 * KIB, arena="serving")
        for slab in a.slabs_on(0):
            arenas = {a.arena_of(0, k) for k in slab.slots.values()}
            assert arenas == {slab.arena}
        per = a.arena_stats()
        assert per["hpc"]["live_bytes"] == 4 * KIB
        assert per["serving"]["live_bytes"] == 4 * KIB
        # two slabs carved even though one could hold both extents
        assert a.stats()["n_slabs"] == 2

    def test_compaction_folds_to_one_partial_slab(self):
        a = SlabAllocator(stripe_bytes=STRIPE)     # 8 slots per 4K slab
        for i in range(32):
            a.place(0, f"k{i}#e0", 4 * KIB)
        for i in range(0, 32, 2):                  # shoot holes in every slab
            a.release(0, f"k{i}#e0")
        before = a.stats()
        moves = a.plan_compaction()
        for mv in moves:
            a.apply_move(mv)
        after = a.stats()
        assert after["n_partial_slabs"] <= 1
        assert after["external_frag_bytes"] <= before["external_frag_bytes"]
        assert after["live_bytes"] == before["live_bytes"]
        assert a.plan_compaction() == []           # fixpoint

    def test_stale_move_rejected(self):
        a = SlabAllocator(stripe_bytes=STRIPE)
        for i in range(16):
            a.place(0, f"k{i}#e0", 4 * KIB)
        for i in range(0, 16, 2):
            a.release(0, f"k{i}#e0")
        moves = a.plan_compaction()
        assert moves
        for mv in moves:
            a.apply_move(mv)
        with pytest.raises(ValueError):
            a.apply_move(moves[0])                 # already committed


class TestAllocRollback:
    """Satellite 1: a failed mid-stripe alloc must leak nothing."""

    def test_mid_stripe_memoryerror_rolls_back_everything(self):
        pool = MemoryPool(1, stripe_bytes=8 * KIB,
                          node_capacity_bytes=24 * KIB)
        with pytest.raises(MemoryError):
            pool.alloc("big", _blob(40 * KIB, seed=1))  # dies at stripe 4
        assert "big" not in pool
        assert pool.physical_bytes() == 0
        assert pool._allocator.stats()["n_extents"] == 0
        assert pool._allocator.stats()["held_bytes"] == 0
        pool.check_no_orphans()
        # the pool is fully usable afterwards
        blob = _blob(16 * KIB, seed=2)
        pool.alloc("fits", blob)
        _assert_all_readable(pool, {"fits": blob})
        pool.check_no_orphans()

    def test_partial_node_failure_rollback_across_nodes(self):
        # node 1 fills first; extents already landed on node 0 roll back
        pool = MemoryPool(2, stripe_bytes=4 * KIB, replication=2,
                          node_capacity_bytes=8 * KIB)
        with pytest.raises(MemoryError):
            pool.alloc("wide", _blob(12 * KIB, seed=3))
        assert pool.physical_bytes() == 0
        pool.check_no_orphans()


class TestOrphanAudit:
    def test_clean_after_drain_and_recover(self):
        pool = MemoryPool(3, stripe_bytes=4 * KIB, replication=2)
        expected = {}
        for i in range(5):
            blob = _blob((i + 1) * 3 * KIB, seed=10 + i)
            pool.alloc(f"o{i}", blob)
            expected[f"o{i}"] = blob
        pool.check_no_orphans()
        pool.add_nodes(1)
        pool.check_no_orphans()
        pool.drain_nodes([1])
        pool.check_no_orphans()
        pool.fail_node(0)
        pool.recover()
        pool.check_no_orphans()
        _assert_all_readable(pool, expected)

    def test_detects_node_orphan(self):
        pool = MemoryPool(2, stripe_bytes=4 * KIB)
        pool.alloc("x", _blob(6 * KIB, seed=1))
        # bypass the pool: an extent lands on a node behind its back
        pool.nodes[0].alloc("ghost#e0", _blob(KIB, seed=2))
        with pytest.raises(OrphanExtentError, match="orphan|drift"):
            pool.check_no_orphans()

    def test_detects_allocator_drift(self):
        pool = MemoryPool(2, stripe_bytes=4 * KIB)
        pool.alloc("x", _blob(6 * KIB, seed=1))
        pool._allocator.place(0, "phantom#e0", KIB)
        with pytest.raises(OrphanExtentError, match="drift"):
            pool.check_no_orphans()


class TestPoolCompaction:
    def test_compaction_preserves_reads_and_reduces_frag(self):
        pool = MemoryPool(2, stripe_bytes=STRIPE)
        expected = {}
        for i in range(24):
            blob = _blob(4 * KIB, seed=i)
            pool.alloc(f"o{i}", blob)
            expected[f"o{i}"] = blob
        for i in range(0, 24, 2):                  # fragment the slabs
            pool.free(f"o{i}")
            del expected[f"o{i}"]
        before = pool.fragmentation_stats()
        stats = pool.compact()
        after = pool.fragmentation_stats()
        assert after["external_frag_bytes"] <= before["external_frag_bytes"]
        assert after["live_bytes"] == before["live_bytes"]
        assert stats["compaction_us"] >= 0.0
        _assert_all_readable(pool, expected)
        pool.check_no_orphans()
        # steady state: a second pass moves nothing
        again = pool.compact()
        assert again["compacted_extents"] == 0
        assert again["moved_extents"] == 0

    def test_compaction_is_charged_on_its_own_timeline(self):
        pool = MemoryPool(2, stripe_bytes=STRIPE)
        for i in range(16):
            pool.alloc(f"o{i}", _blob(4 * KIB, seed=i))
        for i in range(0, 16, 2):
            pool.free(f"o{i}")
        t_main = pool.clock.now("main")
        stats = pool.compact()
        assert stats["compacted_extents"] > 0
        assert pool.clock.now("main") == t_main    # reads don't pay for it
        assert pool.clock.now("compaction") > 0.0


class TestEffectiveCapacitySizing:
    def test_effective_capacity_floors_at_one(self):
        assert effective_node_capacity(10 * KIB) == 10 * KIB
        assert effective_node_capacity(10 * KIB, 4 * KIB) == 6 * KIB
        assert effective_node_capacity(10 * KIB, 100 * KIB) == 1

    def test_pool_nodes_needed(self):
        cap = 16 * KIB
        assert pool_nodes_needed(0, node_capacity_bytes=cap) == 1
        assert pool_nodes_needed(cap, node_capacity_bytes=cap) == 1
        assert pool_nodes_needed(cap + 1, node_capacity_bytes=cap) == 2
        assert pool_nodes_needed(3 * cap, replication=2,
                                 node_capacity_bytes=cap) == 6
        # fragmentation shrinks effective capacity -> more nodes
        assert pool_nodes_needed(3 * cap, node_capacity_bytes=cap,
                                 frag_bytes_per_node=cap / 2) == 6
        # clamps
        assert pool_nodes_needed(100 * cap, node_capacity_bytes=cap,
                                 max_nodes=4) == 4
        assert pool_nodes_needed(1, node_capacity_bytes=cap,
                                 min_nodes=3) == 3


class TestAllocatorProperties:
    """Random interleavings (tentpole property battery): bookkeeping and
    data integrity hold at every step, not just at quiescence."""

    @settings(max_examples=10, deadline=None)
    @given(st.data())
    def test_random_churn_keeps_reads_bit_identical(self, data):
        pool = MemoryPool(data.draw(st.integers(1, 3)),
                          stripe_bytes=8 * KIB,
                          replication=data.draw(st.integers(1, 2)))
        expected: dict[str, np.ndarray] = {}
        arena_of: dict[str, str] = {}
        seq = 0
        for _ in range(data.draw(st.integers(6, 14))):
            op = data.draw(st.sampled_from(
                ["alloc", "free", "write", "resize_obj", "compact",
                 "add_nodes", "drain"]))
            if op == "alloc":
                name = f"obj{seq}"
                seq += 1
                arena = data.draw(st.sampled_from(["hpc", "serving"]))
                blob = _blob(data.draw(st.integers(1, 40)) * KIB, seed=seq)
                pool.alloc(name, blob, client=arena)
                expected[name] = blob
                arena_of[name] = arena
            elif op == "free" and expected:
                name = data.draw(st.sampled_from(sorted(expected)))
                pool.free(name)
                del expected[name]
                del arena_of[name]
            elif op == "write" and expected:
                name = data.draw(st.sampled_from(sorted(expected)))
                seq += 1
                blob = _blob(expected[name].nbytes, seed=1000 + seq)
                pool.write(name, blob, sync=True)
                expected[name] = blob
            elif op == "resize_obj" and expected:
                name = data.draw(st.sampled_from(sorted(expected)))
                seq += 1
                pool.free(name)
                blob = _blob(data.draw(st.integers(1, 40)) * KIB,
                             seed=2000 + seq)
                pool.alloc(name, blob, client=arena_of[name])
                expected[name] = blob
            elif op == "compact":
                before = pool.fragmentation_stats()["external_frag_bytes"]
                pool.compact()
                after = pool.fragmentation_stats()["external_frag_bytes"]
                assert after <= before + 1e-9
            elif op == "add_nodes":
                if len(pool.alive_nodes()) < 5:
                    pool.add_nodes(1)
            elif op == "drain":
                alive = [n.node_id for n in pool.alive_nodes()]
                if len(alive) > 1:
                    pool.drain_nodes([data.draw(st.sampled_from(alive))])
            _assert_all_readable(pool, expected)
            pool.check_no_orphans()
            self._assert_allocator_consistent(pool, arena_of)

    @staticmethod
    def _assert_allocator_consistent(pool, arena_of):
        # allocator extent count == directory replica count
        dir_replicas = sum(
            len(ext.replicas)
            for po in pool._directory.values()
            for ext in po.extents
        )
        s = pool._allocator.stats()
        assert s["n_extents"] == dir_replicas
        # every slab is single-arena and matches the owning object's tenant
        for node in pool.alive_nodes():
            for slab in pool._allocator.slabs_on(node.node_id):
                for key in slab.slots.values():
                    name = key.rsplit("#e", 1)[0]
                    assert slab.arena == arena_of.get(
                        name, slab.arena
                    ), f"{key} in arena {slab.arena}"
                assert 0 < slab.used_slots <= slab.n_slots
        # per-arena live bytes reconcile with the directory
        per = pool._allocator.arena_stats()
        for arena in set(arena_of.values()):
            dir_bytes = sum(
                ext.nbytes * len(ext.replicas)
                for name, po in pool._directory.items()
                for ext in po.extents
                if arena_of[name] == arena
            )
            got = per.get(arena, {"live_bytes": 0})["live_bytes"]
            assert got == dir_bytes, f"arena {arena}: {got} != {dir_bytes}"
