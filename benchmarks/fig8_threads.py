"""Fig 8: multi-thread scaling, DOLMA vs Oracle (1..24 threads).

Uses the two-level scheduler's discrete-event simulation: per-thread private
buffer partitions, thread clusters sharing fabric resources (QPs), prefetch
overlap, and each workload's intrinsic parallel efficiency (applied equally
to both systems). Speedups are self-normalized to 1 thread, exactly as the
paper plots them.
"""
from __future__ import annotations

from repro.core.fabric import INFINIBAND_100G, SimClock
from repro.core.scheduler import TwoLevelScheduler
from repro.hpc import WORKLOADS

from benchmarks.common import emit, save_json

NPB = ["CG", "MG", "FT", "BT", "LU", "IS"]
THREADS = [1, 2, 4, 8, 12, 16, 20, 24]
SCALE = 0.3
SIM_SCALE = 1000.0 / SCALE
N_ITERS = 5
LOCAL_FRACTION = 0.5


def _sim(workload, n_threads: int, *, remote: bool,
         threads_per_cluster: int = 4) -> float:
    w = workload
    compute_us = max(
        w.flops_per_iter * SIM_SCALE / (60.0 * 1e3),
        w.bytes_per_iter * SIM_SCALE / (9.4 * 1e3),
    )
    buffer_bytes = max(
        int(w.fetch_bytes_per_iter * SIM_SCALE * LOCAL_FRACTION), 1 << 16
    )
    sched = TwoLevelScheduler(
        n_threads=n_threads,
        threads_per_cluster=threads_per_cluster,
        buffer_bytes=buffer_bytes,
        dual_buffer=True,
        clock=SimClock(),
        fabric=INFINIBAND_100G,
    )
    fetch = w.fetch_bytes_per_iter if remote else 0
    write = w.write_bytes_per_iter if remote else 0
    return sched.simulate(
        n_iters=N_ITERS,
        compute_us_total=compute_us,
        fetch_bytes_total=int(fetch * SIM_SCALE * (1 - LOCAL_FRACTION)),
        write_bytes_total=int(write * SIM_SCALE),
        parallel_efficiency=w.parallel_efficiency,
    )


def run() -> dict:
    out = {}
    for name in NPB:
        cls = WORKLOADS[name]
        w = cls(scale=SCALE, seed=1)
        # populate the per-iter cost model without running the math
        w.register(_NullRuntime())
        base_dolma = _sim(w, 1, remote=True)
        base_oracle = _sim(w, 1, remote=False)
        rows = []
        for t in THREADS:
            dol = _sim(w, t, remote=True)
            ora = _sim(w, t, remote=False)
            # ablation: one big cluster = no two-level scheduling (all
            # threads contend on a single QP) — the paper's §4.3 mechanism
            flat = _sim(w, t, remote=True, threads_per_cluster=max(t, 1))
            rows.append({
                "threads": t,
                "dolma_speedup": base_dolma / max(dol, 1e-9),
                "oracle_speedup": base_oracle / max(ora, 1e-9),
                "dolma_single_cluster_speedup": base_dolma / max(flat, 1e-9),
            })
        out[name] = rows
        last = rows[-1]
        emit(f"fig8/{name}_24T", 0.0,
             f"dolma={last['dolma_speedup']:.2f}x "
             f"oracle={last['oracle_speedup']:.2f}x "
             f"single_cluster={last['dolma_single_cluster_speedup']:.2f}x")
    save_json("fig8_threads", out)
    return out


class _NullRuntime:
    """Accepts alloc() calls so workloads can publish their cost models."""

    def alloc(self, *a, **k):
        return None


if __name__ == "__main__":
    run()
