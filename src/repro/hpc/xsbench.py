"""XSBench (ECP proxy): Monte Carlo macroscopic cross-section lookups.

Paper Table 1: random, lookup-intensive access; 5.5 GB total, 5.1 remote,
R/W 1:1, object index_grid (the unionized energy grid).
"""
from __future__ import annotations

import numpy as np

from repro.hpc.base import HPCWorkload


class XSBench(HPCWorkload):
    name = "XSBench"
    characteristics = "Random access, lookup intensive"
    paper_total_gb = 5.5
    paper_remote_gb = 5.1
    read_write_ratio = "1:1"
    parallel_efficiency = 0.99  # embarrassingly parallel lookups

    N_NUCLIDES = 64
    LOOKUPS = 50_000

    def __init__(self, scale: float = 1.0, seed: int = 0):
        super().__init__(scale, seed)
        grid_bytes = self._target_bytes(5.1)
        self.n_gp = max(grid_bytes // (8 * self.N_NUCLIDES), 1024)
        self.energy = np.sort(self.rng.random(self.n_gp))
        self.grid0 = self.rng.random((self.n_gp, self.N_NUCLIDES))

    def register(self, rt):
        rt.alloc("index_grid", self.grid0, reads_per_iter=1, writes_per_iter=0)
        rt.alloc("energy_grid", self.energy, reads_per_iter=1, writes_per_iter=0)
        rt.alloc("tally", np.zeros(8), reads_per_iter=1, writes_per_iter=1)
        self.flops_per_iter = self.LOOKUPS * (np.log2(self.n_gp) + 10)
        self.bytes_per_iter = self.LOOKUPS * (8 * self.N_NUCLIDES + 64)
        self.fetch_bytes_per_iter = self.grid0.nbytes
        self.write_bytes_per_iter = 0

    def iterate(self, rt, it):
        energy = rt.fetch("energy_grid")
        rng = np.random.default_rng(1234 + it)
        samples = rng.random(self.LOOKUPS)
        # binary searches on the energy grid — the big xs grid prefetches
        idx = np.clip(np.searchsorted(energy, samples) - 1, 0, self.n_gp - 2)
        frac = (samples - energy[idx]) / np.maximum(
            energy[idx + 1] - energy[idx], 1e-12
        )
        self.charge(rt, 0.3)
        grid = rt.fetch("index_grid")
        xs = grid[idx] * (1 - frac)[:, None] + grid[idx + 1] * frac[:, None]
        macro = xs.sum(axis=1)
        self.charge(rt, 0.5)
        tally = rt.fetch("tally")
        tally = tally + np.array([
            macro.sum(), macro.max(), macro.min(), float(idx.sum() % 997),
            0, 0, 0, 0,
        ])
        rt.commit("tally", tally)
        self.charge(rt, 0.2)

    def checksum(self, rt):
        return float(rt.fetch("tally")[0])
