"""Emit EXPERIMENTS.md markdown tables from the benchmark/dry-run artifacts."""
from __future__ import annotations

import json
import pathlib

from benchmarks import roofline

RESULTS = pathlib.Path(__file__).resolve().parent / "results"


def dryrun_table() -> str:
    rows = roofline.run("*__16x16.json")
    out = [
        "| arch | cell | comp_s | mem_s [lo,hi] | coll_s | dominant | useful | roofline | HBM GB/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if "status" in r:
            out.append(
                f"| {r['arch']} | {r['cell']} | — | — | — | {r['status']} | — | — | — |"
            )
            continue
        out.append(
            f"| {r['arch']} | {r['cell']} | {r['compute_s']:.4f} "
            f"| {r['memory_s']:.4f}, {r['memory_upper_s']:.4f} "
            f"| {r['collective_s']:.4f} | {r['dominant']} "
            f"| {r['useful_fraction']:.3f} | {100*r['roofline_fraction']:.1f}% "
            f"| {r['hbm_peak_gb']:.1f} |"
        )
    return "\n".join(out)


def multipod_table() -> str:
    rows = roofline.run("*__2x16x16.json")
    out = [
        "| arch | cell | comp_s | mem_s | coll_s | dominant | HBM GB/dev |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if "status" in r:
            out.append(f"| {r['arch']} | {r['cell']} | — | — | — | {r['status']} | — |")
            continue
        out.append(
            f"| {r['arch']} | {r['cell']} | {r['compute_s']:.4f} "
            f"| {r['memory_s']:.4f} | {r['collective_s']:.4f} | {r['dominant']} "
            f"| {r['hbm_peak_gb']:.1f} |"
        )
    return "\n".join(out)


def fig7_table() -> str:
    data = json.loads((RESULTS / "fig7_workloads.json").read_text())
    fr = [r["fraction"] for r in next(iter(data["table"].values()))["rows"]]
    out = ["| workload | " + " | ".join(f"{int(f*100)}%" for f in fr) + " |",
           "|---|" + "---|" * len(fr)]
    for name, t in data["table"].items():
        out.append(
            f"| {name} | "
            + " | ".join(f"{r['slowdown']:.2f}x" for r in t["rows"]) + " |"
        )
    out.append("")
    out.append(f"Average best memory saving at <=16% slowdown: "
               f"**{data['avg_saving_at_16pct_slowdown']:.1%}** "
               f"(paper: up to 63%).")
    return "\n".join(out)


def main() -> None:
    print("## Single-pod roofline (16x16)\n")
    print(dryrun_table())
    print("\n## Multi-pod (2x16x16)\n")
    print(multipod_table())
    print("\n## Fig 7\n")
    print(fig7_table())


if __name__ == "__main__":
    main()
