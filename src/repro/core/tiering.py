"""Compiled-graph tiering: DOLMA placement applied to a JAX step function.

Two backends realize a :class:`PlacementPlan` inside the compiled graph:

* ``host_offload`` — REMOTE leaves get ``memory_kind="pinned_host"`` on their
  sharding: HBM is the local tier, host DRAM the remote tier. Fetch = a
  device copy XLA schedules; the dual buffer is the explicit next-layer
  prefetch carried through :func:`tiered_scan`.
* ``fsdp_stream`` — REMOTE leaves are sharded along the data axis and
  all-gathered per layer inside the scan (peer HBM is the remote tier). This
  is pure SPMD and compiles on every backend; it is the default for the
  multi-pod dry-run.

Either way, :func:`tiered_scan` provides the paper's dual-buffer shape: the
scan carry holds the *current* layer's materialized weights while the *next*
layer's fetch is issued before the current layer's compute — so the fetch has
no data dependence on the compute and the scheduler can overlap them. The
"access barrier deferred to first use" (§5) is the data dependence of layer
k+1's first matmul on its own gather, rather than a global barrier.

:func:`tiered_scan` is the single engine for the layer loop; it composes the
dual buffer with sqrt-L activation checkpointing instead of treating them as
mutually exclusive:

* **remat off** — a flat scan whose carry holds the next layer's fetched
  weights (the classic dual buffer).
* **remat on** — depth ``L`` splits into ``n_outer`` checkpointed blocks of
  ``n_inner`` layers (:func:`_block_split`). The dual-buffer carry lives in
  the *inner* scan, entirely inside each block's remat boundary: the gathered
  weights are recomputed during the block's backward pass, never saved across
  the forward — prefetch no longer defeats FSDP/offload. Only ``n_outer``
  activation carries persist, and a ``remote_carry_fn`` hook can place those
  on the remote tier (``pinned_host`` where the SPMD partitioner takes it,
  the fsdp-sharded spec otherwise) so saved activations obey the same
  placement budget as weights. The cost of the boundary is one unoverlapped
  fetch per block (the first layer's weights cannot be prefetched from the
  previous block without being saved).

The anti-hoisting barrier between the carry stack and the layer body is
:func:`grad_safe_barrier` — a ``jax.custom_vjp`` identity that applies
``jax.lax.optimization_barrier`` in both the forward and backward pass. The
raw primitive has no differentiation rule (``jax.grad`` through it raises
``NotImplementedError``), so the custom VJP both fixes autodiff by
construction and keeps the barrier's scheduling effect on the cotangents.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Literal

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.metadata import Tier
from repro.core.objects import ObjectCatalog, ObjectKind
from repro.core.placement import PlacementPlan, PlacementPolicy

TieringMode = Literal["none", "host_offload", "fsdp_stream"]


@dataclasses.dataclass(frozen=True)
class TieringConfig:
    """How params/opt-state tier out of HBM during the layer scan.

    ``local_fraction`` is the share of (param + opt state) bytes kept
    resident (or ``"auto"`` to let the sizing solver pick it);
    ``degradation_target`` is the slowdown fraction the solver sizes for
    (0.16 = paper knee). ``prefetch`` enables the dual-buffer weight
    fetch one layer ahead of compute.
    """

    mode: TieringMode = "fsdp_stream"
    # Fraction of (param + opt state) bytes allowed to stay in HBM; "auto"
    # defers to the cost-model sizing solver (plan_for_params needs a
    # WorkloadProfile then — see repro.core.sizing).
    local_fraction: float | str = 1.0
    # Degradation target the "auto" solver sizes for (paper knee: 16%).
    degradation_target: float = 0.16
    prefetch: bool = True  # dual-buffer prefetch in the layer scan
    # Keep the dual buffer on when the layer scan is rematerialized: the
    # prefetch carry moves inside the block-level remat boundary (recomputed,
    # not saved). Off = the pre-unification behaviour (prefetch only without
    # remat, overlap left to XLA's latency-hiding scheduler).
    prefetch_under_remat: bool = True
    # Which axis FSDP-shards the remote leaves over.
    fsdp_axis: str = "data"


@functools.cache
def supports_host_offload() -> bool:
    """Probe whether the current backend accepts pinned_host memory kinds."""
    try:
        dev = jax.devices()[0]
        sharding = jax.sharding.SingleDeviceSharding(dev, memory_kind="pinned_host")
        x = jax.device_put(jnp.zeros((8,), jnp.float32), sharding)
        jax.block_until_ready(x)
        return True
    except Exception:  # noqa: BLE001 - backend support probe
        return False


@functools.cache
def _offload_spmd_probe(mesh_shape: tuple, mesh_axes: tuple) -> bool:
    try:
        mesh = jax.make_mesh(mesh_shape, mesh_axes)
        dev_sh = NamedSharding(mesh, P(None, mesh_axes[-1]))
        host_sh = NamedSharding(mesh, P(None, mesh_axes[-1]),
                                memory_kind="pinned_host")

        def step(p, m):
            m2 = 0.9 * m + 0.1 * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - m2).astype(p.dtype), m2

        pa = jax.ShapeDtypeStruct((16, mesh.shape[mesh_axes[-1]] * 8), jnp.bfloat16)
        ma = jax.ShapeDtypeStruct(pa.shape, jnp.float32)
        jax.jit(step, in_shardings=(dev_sh, host_sh),
                out_shardings=(dev_sh, host_sh)).lower(pa, ma).compile()
        return True
    except Exception:  # noqa: BLE001 - backend support probe
        return False


def supports_host_offload_spmd(mesh: jax.sharding.Mesh) -> bool:
    """Whether pinned_host in/out shardings compile under SPMD on this mesh.

    True on TPU backends; False on XLA-CPU (the dry-run container), which
    rejects memory-space annotations in the SPMD partitioner — the optimizer
    then falls down the bf16/int8 moment ladder instead (DESIGN.md §2).
    """
    return _offload_spmd_probe(
        tuple(mesh.shape.values()), tuple(mesh.shape.keys())
    )


def plan_for_params(
    params: Any,
    *,
    config: TieringConfig,
    opt_state: Any = None,
    access_counts: dict[str, int] | None = None,
    profile: Any = None,
    telemetry: Any = None,
) -> PlacementPlan:
    """Build a placement plan over the persistent objects of a train step.

    Parameters are read every step (forward + backward ⇒ 2 reads, 1 write);
    optimizer moments are read+written once. Those defaults reproduce the
    policy inputs DOLMA's allocator interposition observes; callers may
    override with measured ``access_counts`` from an ObjectCatalog trace.

    With ``config.local_fraction == "auto"`` the HBM budget is chosen by the
    quantitative sizing solver: pass a recorded ``WorkloadProfile``, or omit
    ``profile`` to have one synthesized from this catalog (each leaf fetched
    once per step — :func:`repro.core.sizing.synthetic_profile`) with the
    step's compute time estimated from leaf bytes at HBM bandwidth.
    """
    catalog = ObjectCatalog()
    for path, leaf in jax.tree_util.tree_leaves_with_path(params):
        name = "params" + jax.tree_util.keystr(path)
        from repro.core.objects import DataObject

        n_reads = (access_counts or {}).get(name, 2)
        catalog.add(
            DataObject(
                name=name,
                shape=tuple(leaf.shape),
                dtype=leaf.dtype,
                kind=ObjectKind.PARAM,
                n_reads=n_reads,
                n_writes=1,
            )
        )
    if opt_state is not None:
        for path, leaf in jax.tree_util.tree_leaves_with_path(opt_state):
            name = "opt" + jax.tree_util.keystr(path)
            from repro.core.objects import DataObject

            catalog.add(
                DataObject(
                    name=name,
                    shape=tuple(leaf.shape),
                    dtype=leaf.dtype,
                    kind=ObjectKind.OPT_STATE,
                    n_reads=1,
                    n_writes=1,
                )
            )
    if config.local_fraction == "auto" and profile is None:
        from repro.core.fabric import TPU_V5E_HBM_GBPS
        from repro.core.sizing import synthetic_profile

        # one read of every leaf per step at HBM stream rate approximates the
        # step's compute floor — enough for the solver to price demotions
        compute_us = catalog.total_bytes / (TPU_V5E_HBM_GBPS * 1e3)
        profile = synthetic_profile(catalog, compute_us_per_step=compute_us,
                                    source="plan_for_params")
    plan = PlacementPolicy().plan(
        catalog,
        local_fraction=config.local_fraction,
        profile=profile,
        degradation_target=config.degradation_target,
    )
    if telemetry is not None and telemetry.enabled:
        telemetry.instant("tiering.plan", track="tiering", t_us=0.0,
                          **plan.summary())
        telemetry.gauge("tiering.local_bytes", plan.local_bytes)
        telemetry.gauge("tiering.remote_bytes", plan.remote_bytes)
    return plan


def leaf_sharding(
    mesh: jax.sharding.Mesh,
    spec: P,
    *,
    tier: Tier,
    config: TieringConfig,
    leading_dim: int | None = None,
) -> NamedSharding:
    """Sharding for one leaf given its DOLMA tier."""
    if tier is Tier.REMOTE:
        if config.mode == "host_offload" and supports_host_offload():
            return NamedSharding(mesh, spec, memory_kind="pinned_host")
        if config.mode == "fsdp_stream":
            # shard the leading (stacked-layer) dim over the fsdp axis when
            # divisible; otherwise fall back to the base spec.
            if leading_dim is not None and config.fsdp_axis in mesh.shape:
                ax = mesh.shape[config.fsdp_axis]
                if leading_dim % ax == 0 and (not spec or spec[0] is None):
                    new_spec = P(config.fsdp_axis, *tuple(spec)[1:]) if spec else P(
                        config.fsdp_axis
                    )
                    return NamedSharding(mesh, new_spec)
    return NamedSharding(mesh, spec)


@jax.custom_vjp
def grad_safe_barrier(x):
    """Identity whose forward AND backward apply an XLA optimization barrier.

    ``jax.lax.optimization_barrier`` has no differentiation rule, so placing
    it raw between the carry stack and the layer body makes every grad-taking
    caller crash with ``NotImplementedError``. This custom-VJP identity keeps
    the barrier's anti-hoisting effect (stops XLA moving a convert of the
    whole saved-carry stack out of the backward loop, which would materialize
    a full-precision copy of every saved carry) while being transparent to
    autodiff: the cotangent passes through its own barrier, so the backward
    loop gets the same scheduling fence.
    """
    return jax.lax.optimization_barrier(x)


def _gsb_fwd(x):
    return jax.lax.optimization_barrier(x), None


def _gsb_bwd(_res, g):
    return (jax.lax.optimization_barrier(g),)


grad_safe_barrier.defvjp(_gsb_fwd, _gsb_bwd)


def _block_split(n_layers: int) -> tuple[int, int]:
    """Factor ``n_layers = n_outer * n_inner`` minimizing ``n_outer + n_inner``.

    Roles are explicit: ``n_outer`` is the number of checkpointed blocks (the
    count of carries *saved* across the forward), ``n_inner`` the layers per
    block (the transient recompute depth during one block's backward). Only
    exact factorizations are produced — ``n_outer * n_inner == n_layers``
    always — so prime depths degenerate to ``(1, n_layers)``: a single block,
    full recompute. ``n_outer <= n_inner`` by construction (the smaller
    divisor is taken as the saved-carry count).
    """
    if n_layers < 1:
        raise ValueError(f"_block_split: n_layers must be >= 1, got {n_layers}")
    best = (1, n_layers)
    for n_outer in range(1, int(n_layers ** 0.5) + 1):
        if n_layers % n_outer == 0:
            n_inner = n_layers // n_outer
            if n_outer + n_inner < best[0] + best[1]:
                best = (n_outer, n_inner)
    assert best[0] * best[1] == n_layers, (
        f"_block_split produced ragged blocking {best} for depth {n_layers}"
    )
    return best


def _check_stack_depth(stacked_params: Any, n_layers: int) -> None:
    leads = {
        t.shape[0] for t in jax.tree.leaves(stacked_params) if jnp.ndim(t) >= 1
    }
    if leads and leads != {n_layers}:
        raise ValueError(
            f"tiered_scan: stacked_params leading dims {sorted(leads)} do not "
            f"all equal n_layers={n_layers}; the scan would silently "
            "mis-block. Slice or restack the params to the depth you scan."
        )


def _default_fetch(stacked, i):
    return jax.tree.map(
        lambda x: jax.lax.dynamic_index_in_dim(x, i, 0, keepdims=False),
        stacked,
    )


def tiered_scan(
    layer_fn: Callable[[Any, Any], Any],
    carry: Any,
    stacked_params: Any,
    *,
    n_layers: int,
    remat: bool = False,
    policy=None,
    prefetch: bool = True,
    fetch_fn: Callable[[Any, jax.Array], Any] | None = None,
    min_layers: int = 12,
    unroll: int = 1,
    remote_carry_fn: Callable[[Any], Any] | None = None,
):
    """Scan ``layer_fn`` over ``n_layers`` stacked layers: the unified engine.

    ``stacked_params``: pytree whose leaves have leading dim ``n_layers``
    (possibly host-offloaded / FSDP-sharded). ``fetch_fn(stacked, i)``
    materializes layer *i*'s weights in the local tier (default: dynamic
    index, which XLA turns into a copy/all-gather per the leaves' shardings).

    ``prefetch=True`` gives the paper's dual buffer: the carry holds the next
    layer's materialized weights, fetched one step ahead of use so the
    fetch has no data dependence on the current layer's compute. Prefetch
    changes only *when* fetches are issued, never their indices or the math
    on the carry — loss and grads are bit-identical to ``prefetch=False``.

    ``remat=True`` composes that with two-level (sqrt-L) checkpointing:
    ``n_outer`` blocks of ``n_inner`` layers (:func:`_block_split`), each
    block ``jax.checkpoint``-ed, each layer ``jax.checkpoint``-ed inside it.
    The dual-buffer carry lives in the inner scan, inside the block's remat
    boundary: gathered weights are recomputed during the block's backward,
    not saved across the forward. Saved state is ``n_outer`` activation
    carries (+ ``n_inner`` transiently during one block's backward; with
    prefetch also ``n_inner`` transient weight fetches). Depths below
    ``min_layers`` use flat per-layer remat (``n_outer=n_layers``,
    ``n_inner=1``) — fewer recomputes at O(L) saved carries; prefetch has
    nothing to overlap inside a 1-layer block and degenerates gracefully.

    ``remote_carry_fn`` (remat only) is applied to each saved outer-block
    carry — the hook that places persistent activation memory on the remote
    tier (see :func:`remote_carry_placer`).
    """
    _check_stack_depth(stacked_params, n_layers)
    if fetch_fn is None:
        fetch_fn = _default_fetch

    if not remat:
        if not prefetch:
            def body(c, i):
                """Demand-fetch scan step: fetch layer ``i``, then compute."""
                return layer_fn(c, fetch_fn(stacked_params, i)), None

            carry, _ = jax.lax.scan(
                body, carry, jnp.arange(n_layers), unroll=unroll
            )
            return carry

        p0 = fetch_fn(stacked_params, jnp.asarray(0, jnp.int32))

        def body(state, i):
            """Dual-buffer scan step: post fetch ``i+1``, compute layer ``i``."""
            c, cur = state
            # issue the next fetch *before* compute: no data dependence
            # between them, so the scheduler overlaps DMA/all-gather with
            # the matmuls.
            nxt = fetch_fn(
                stacked_params,
                jnp.minimum(i + 1, n_layers - 1).astype(jnp.int32),
            )
            c = layer_fn(c, cur)
            return (c, nxt), None

        (carry, _), _ = jax.lax.scan(
            body, (carry, p0), jnp.arange(n_layers), unroll=unroll
        )
        return carry

    # --- remat path ---------------------------------------------------------
    n_outer, n_inner = (
        (n_layers, 1) if n_layers < min_layers else _block_split(n_layers)
    )

    # per-layer checkpoint; the fetch sits inside the boundary so the weight
    # gather is re-issued (not stored) when this layer's backward recomputes
    def layer_at(c, i):
        """One rematerialized layer, fetching its own weights by index."""
        return layer_fn(grad_safe_barrier(c), fetch_fn(stacked_params, i))

    layer_at = jax.checkpoint(layer_at, policy=policy)

    # prefetch variant: current weights arrive via the (inner) carry
    def layer_with(c, p):
        """One rematerialized layer, weights arriving via the scan carry."""
        return layer_fn(grad_safe_barrier(c), p)

    layer_with = jax.checkpoint(layer_with, policy=policy)

    def block_fn(c, start):
        """Layers [start, start + n_inner) — runs inside one remat boundary."""
        if not prefetch or n_inner == 1:
            def body(cc, j):
                """Demand-fetch step inside the remat block."""
                return layer_at(cc, start + j), None

            c, _ = jax.lax.scan(
                body, c, jnp.arange(n_inner, dtype=jnp.int32), unroll=unroll
            )
            return c

        # dual buffer inside the boundary: p0 and every carried fetch are
        # recomputed during this block's backward, never saved forward
        p0 = fetch_fn(stacked_params, start)

        def body(state, j):
            """Dual-buffer step inside the remat block (fetches recomputed)."""
            cc, cur = state
            nxt = fetch_fn(
                stacked_params,
                jnp.minimum(start + j + 1, n_layers - 1).astype(jnp.int32),
            )
            cc = layer_with(cc, cur)
            return (cc, nxt), None

        (c, _), _ = jax.lax.scan(
            body, (c, p0), jnp.arange(n_inner, dtype=jnp.int32), unroll=unroll
        )
        return c

    if n_inner > 1:  # flat mode keeps the single (per-layer) checkpoint level
        block_fn = jax.checkpoint(block_fn, policy=policy)

    def outer_body(c, g):
        """Run remat block ``g`` and re-place its saved carry off-HBM."""
        c = block_fn(c, (g * n_inner).astype(jnp.int32))
        if remote_carry_fn is not None:
            c = remote_carry_fn(c)
        return c, None

    if remote_carry_fn is not None:
        carry = remote_carry_fn(carry)  # the initial carry is saved too
    carry, _ = jax.lax.scan(
        outer_body, carry, jnp.arange(n_outer, dtype=jnp.int32)
    )
    return carry


def remote_carry_placer(
    mesh: jax.sharding.Mesh | None,
    config: TieringConfig | None = None,
    *,
    spec_fn: Callable[[Any], P] | None = None,
) -> Callable[[Any], Any] | None:
    """Build a ``remote_carry_fn`` placing saved block carries off-HBM.

    Where the SPMD partitioner accepts memory-space annotations
    (:func:`supports_host_offload_spmd` — TPU backends), each saved carry
    leaf is constrained to its own spec with ``memory_kind="pinned_host"``:
    a pure memory-space move, no resharding, host DRAM as the remote tier.
    Otherwise the leaf is constrained to the (fsdp-/batch-sharded) spec
    itself — peer HBM as the remote tier, the ``fsdp_stream`` realization.
    Returns ``None`` when there is no mesh (single-host tests).

    ``spec_fn(leaf) -> PartitionSpec`` supplies the logical spec of a carry
    leaf (callers resolve their own activation axis names); default is
    fully replicated, which is only sensible for host offload.
    """
    if mesh is None:
        return None
    config = config or TieringConfig()
    host = config.mode != "none" and supports_host_offload_spmd(mesh)

    def spec_of(leaf) -> P:
        """Logical partition spec of a carry leaf (default: replicated)."""
        if spec_fn is not None:
            return spec_fn(leaf)
        return P(*([None] * jnp.ndim(leaf)))

    def place_leaf(leaf):
        """Constrain one carry leaf to the remote tier (host or peer HBM)."""
        if jnp.ndim(leaf) < 2:  # scalars / small aux stay local
            return leaf
        spec = spec_of(leaf)
        if host:
            sharding = NamedSharding(mesh, spec, memory_kind="pinned_host")
        else:
            sharding = NamedSharding(mesh, spec)
        return jax.lax.with_sharding_constraint(leaf, sharding)

    return lambda c: jax.tree.map(place_leaf, c)


# ---------------------------------------------------------------------------
# deprecated shims — PR 2 unified both scans into tiered_scan
# ---------------------------------------------------------------------------

def prefetch_scan(
    layer_fn: Callable[[Any, Any], Any],
    carry: Any,
    stacked_params: Any,
    *,
    n_layers: int,
    prefetch: bool = True,
    fetch_fn: Callable[[Any, jax.Array], Any] | None = None,
    unroll: int = 1,
):
    """Deprecated: use :func:`tiered_scan` (``remat=False``)."""
    return tiered_scan(
        layer_fn, carry, stacked_params, n_layers=n_layers, remat=False,
        prefetch=prefetch, fetch_fn=fetch_fn, unroll=unroll,
    )


def blocked_remat_scan(layer_fn, carry, stacked_params, *, n_layers: int,
                       policy=None, min_layers: int = 12):
    """Deprecated: use :func:`tiered_scan` (``remat=True``)."""
    return tiered_scan(
        layer_fn, carry, stacked_params, n_layers=n_layers, remat=True,
        policy=policy, prefetch=False, min_layers=min_layers,
    )
