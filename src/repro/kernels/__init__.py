"""Pallas kernels + the backend auto-detection the whole package shares.

Every kernel in this package has two execution modes: compiled Pallas (TPU
Mosaic) and ``interpret=True`` (the kernel body runs as traced jax ops, so
the same code validates on CPU CI hosts). :func:`kernel_backend` picks the
right one for the current platform — compiled on TPU, interpret elsewhere —
and can be forced either way with the ``REPRO_KERNEL_BACKEND`` environment
variable (``pallas`` | ``interpret`` | ``auto``). All three kernels
(``streaming_matmul``, ``flash_attention``, ``ssd_scan``) and the tests
resolve their ``interpret=None`` default through :func:`resolve_interpret`,
so there is exactly one place where the platform decision lives.
"""
from __future__ import annotations

import os

import jax

#: Environment override for the kernel execution mode.
BACKEND_ENV = "REPRO_KERNEL_BACKEND"

_VALID_BACKENDS = ("auto", "pallas", "interpret")


def kernel_backend() -> str:
    """``"pallas"`` (compiled Mosaic) or ``"interpret"``.

    Resolution order: the ``REPRO_KERNEL_BACKEND`` env var if set (``auto``
    defers), else compiled Pallas exactly when the default jax device is a
    TPU. Raises :class:`ValueError` for an unknown override value so typos
    fail loudly instead of silently falling back to a 100x slower mode.
    """
    choice = os.environ.get(BACKEND_ENV, "auto").strip().lower()
    if choice not in _VALID_BACKENDS:
        raise ValueError(
            f"{BACKEND_ENV}={choice!r}: expected one of {_VALID_BACKENDS}"
        )
    if choice != "auto":
        return choice
    return "pallas" if jax.devices()[0].platform == "tpu" else "interpret"


def resolve_interpret(interpret: bool | None) -> bool:
    """Map a kernel's ``interpret`` argument (``None`` = auto) to a bool."""
    if interpret is None:
        return kernel_backend() == "interpret"
    return bool(interpret)
