"""NPB IS: integer (counting) sort.

Paper Table 1: sequential, parallel access; 32.3 GB total, 32.0 remote,
R/W 1:1, objects key_array, key_buf2.
"""
from __future__ import annotations

import numpy as np

from repro.hpc.base import HPCWorkload


class IS(HPCWorkload):
    name = "IS"
    characteristics = "Sequential, parallel access"
    paper_total_gb = 32.3
    paper_remote_gb = 32.0
    read_write_ratio = "1:1"
    parallel_efficiency = 0.7

    MAX_KEY = 1 << 16

    def __init__(self, scale: float = 1.0, seed: int = 0):
        super().__init__(scale, seed)
        per_obj = self._target_bytes(32.3) // 2
        self.n = max(per_obj // 4, 1 << 16)
        self.keys0 = self.rng.integers(
            0, self.MAX_KEY, size=self.n, dtype=np.int32
        )

    def register(self, rt):
        rt.alloc("key_array", self.keys0, reads_per_iter=1, writes_per_iter=1)
        rt.alloc("key_buf2", np.zeros(self.n, np.int32),
                 reads_per_iter=0, writes_per_iter=1)
        self.flops_per_iter = 4 * self.n
        self.bytes_per_iter = 4 * 6 * self.n
        self.fetch_bytes_per_iter = self.n * 4
        self.write_bytes_per_iter = 2 * self.n * 4

    def iterate(self, rt, it):
        keys = rt.fetch("key_array")
        counts = np.bincount(keys, minlength=self.MAX_KEY)
        self.charge(rt, 0.6)
        sorted_keys = np.repeat(
            np.arange(self.MAX_KEY, dtype=np.int32), counts
        )
        # NPB IS perturbs keys between rankings; rotate deterministically
        new_keys = np.roll(keys, 7) ^ (it + 1)
        new_keys = np.clip(new_keys, 0, self.MAX_KEY - 1).astype(np.int32)
        rt.commit("key_buf2", sorted_keys)
        rt.commit("key_array", new_keys)
        self.charge(rt, 0.4)

    def checksum(self, rt):
        buf = rt.fetch("key_buf2")
        return float(buf[:: max(len(buf) // 1024, 1)].sum())
